//! Actor-runtime microbenchmarks (§Perf): message throughput, per-action
//! scheduling overhead, compile latency for a paper-scale plan, and the
//! static-memory-plan contrast — steady-state ns/step and allocations/step
//! for the pooled (arena-backed) vs allocating execution paths on a real
//! training loop. Results are printed as tables **and** written to
//! `BENCH_actor_micro.json` so the perf trajectory accumulates machine-
//! readably; `--quick` shrinks the workload to a CI smoke check.

use oneflow::actor::{DataSource, Engine, FnSource, RunOptions, RunReport};
use oneflow::bench::{time_n, Table};
use oneflow::compiler::{compile, CompileOptions, InputBinding, PhysPlan};
use oneflow::config::Args;
use oneflow::data::SyntheticCorpus;
use oneflow::graph::{LogicalGraph, OpKind};
use oneflow::models::{gpt_pipeline_real, gpt_sim, GptPipelineConfig, GptSimConfig};
use oneflow::placement::Placement;
use oneflow::runtime::{AllocatingBackend, Backend, NativeBackend, SimBackend};
use oneflow::linalg::{self, MatRef};
use oneflow::sbp::{s, NdSbp};
use oneflow::tensor::{DType, Tensor};
use oneflow::util::{fmt, Rng};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn chain_plan(len: usize, ndev: usize) -> PhysPlan {
    let p = Placement::node(0, ndev);
    let mut g = LogicalGraph::new();
    let mut t = g.add1("x", OpKind::Input { shape: [ndev, 4].into(), dtype: DType::F32 }, &[], p.clone());
    g.hint_tensor(t, NdSbp::d1(s(0)));
    for i in 0..len {
        t = g.add1(format!("id{i}"), OpKind::Identity, &[t], p.clone());
    }
    compile(&g, &[t], &HashMap::new(), &CompileOptions { fuse: false, ..Default::default() })
}

/// A 1-stage real-numerics GPT training loop (input, var, compute and
/// update actors; no transfers) — the steady-state workload.
fn train_plan() -> PhysPlan {
    let cfg = GptPipelineConfig {
        stages: 1,
        vocab: 32,
        hidden: 16,
        ff: 32,
        blocks_per_stage: 1,
        rows: 32,
        lr: 0.2,
        microbatches: 1,
    };
    let (g, loss, upd) = gpt_pipeline_real(&cfg);
    compile(&g, &[loss], &upd, &CompileOptions::default())
}

fn train_source() -> Arc<dyn DataSource> {
    let corpus = Arc::new(SyntheticCorpus::new(2048, 32, 29));
    Arc::new(FnSource(move |b: &InputBinding, piece: usize| {
        let (ids, labels) = corpus.batch(piece, 1, 32);
        match b.name.as_str() {
            "ids" => Tensor::new([32], DType::I32, ids.data),
            "labels" => Tensor::new([32], DType::I32, labels.data),
            _ => Tensor::full(b.shape.clone(), b.dtype, 1.0),
        }
    }))
}

fn timed_run(plan: &PhysPlan, backend: &Arc<dyn Backend>, pieces: usize) -> f64 {
    time_n(1, 3, || {
        let r = Engine::new(plan.clone(), backend.clone())
            .with_source(train_source())
            .run_with(RunOptions { pieces, timeout: Some(Duration::from_secs(300)) })
            .expect("bench run failed");
        assert_eq!(r.pieces, pieces);
    })
    .mean_secs
}

/// Marginal cost of one additional steady-state step: timing a long and a
/// short run and taking the slope cancels the per-run fixed costs (engine
/// construction, queue-thread spawn/join, warm-up, teardown) that a naive
/// wall/pieces division would smear into the step time.
fn steady_state(plan: &PhysPlan, backend: Arc<dyn Backend>, pieces: usize) -> (f64, RunReport) {
    let short = (pieces / 4).max(1);
    let t_long = timed_run(plan, &backend, pieces);
    let t_short = timed_run(plan, &backend, short);
    let per_step = ((t_long - t_short) / (pieces - short) as f64).max(0.0);
    let report = Engine::new(plan.clone(), backend)
        .with_source(train_source())
        .run_with(RunOptions { pieces, timeout: Some(Duration::from_secs(300)) })
        .expect("bench report run failed");
    (per_step, report)
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let pieces = if quick { 40 } else { 200 };
    let mut json = String::from("{\n  \"bench\": \"actor_micro\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));

    let mut tab = Table::new("Actor runtime microbenchmarks", &["metric", "value"]);

    // 1. steady-state training step: pooled (arena-backed) vs allocating.
    // Identical plan, identical data, bitwise-identical losses — only the
    // buffer strategy differs (Backend::execute_into vs the fallback).
    let plan = train_plan();
    let (pooled_step, pooled_rep) = steady_state(&plan, Arc::new(NativeBackend), pieces);
    let (alloc_step, alloc_rep) =
        steady_state(&plan, Arc::new(AllocatingBackend(NativeBackend)), pieces);
    let per_step_allocs = |r: &RunReport| r.buffer_allocs as f64 / r.pieces as f64;
    tab.row(&["steady-state step (pooled)".into(), fmt::secs(pooled_step)]);
    tab.row(&["steady-state step (allocating)".into(), fmt::secs(alloc_step)]);
    tab.row(&[
        "allocations/step (pooled, incl. warm-up)".into(),
        format!("{:.2}", per_step_allocs(&pooled_rep)),
    ]);
    tab.row(&[
        "allocations/step (allocating)".into(),
        format!("{:.2}", per_step_allocs(&alloc_rep)),
    ]);
    json.push_str(&format!(
        "  \"steady_state\": {{\n    \"pieces\": {pieces},\n    \
         \"pooled\": {{\"ns_per_step\": {:.0}, \"allocs_total\": {}, \"allocs_per_step\": {:.4}}},\n    \
         \"allocating\": {{\"ns_per_step\": {:.0}, \"allocs_total\": {}, \"allocs_per_step\": {:.4}}}\n  }},\n",
        pooled_step * 1e9,
        pooled_rep.buffer_allocs,
        per_step_allocs(&pooled_rep),
        alloc_step * 1e9,
        alloc_rep.buffer_allocs,
        per_step_allocs(&alloc_rep),
    ));

    // 2. end-to-end actions/second through the full protocol (1 queue thread)
    let chain_pieces = if quick { 50 } else { 200 };
    let plan1 = chain_plan(64, 1);
    let timing = time_n(1, if quick { 2 } else { 5 }, || {
        let engine = Engine::new(plan1.clone(), Arc::new(SimBackend));
        let r = engine.run(chain_pieces);
        assert_eq!(r.pieces, chain_pieces);
    });
    let actions = (64 + 2) * chain_pieces; // +input +fetch
    let per_action = timing.mean_secs / actions as f64;
    tab.row(&["chain actions/s (1 thread)".into(), fmt::rate(1.0 / per_action)]);
    tab.row(&["per-action overhead".into(), fmt::secs(per_action)]);

    // 3. cross-thread message cost: same chain split over 4 devices
    let plan4 = chain_plan(64, 4);
    let t4 = time_n(1, if quick { 2 } else { 5 }, || {
        let engine = Engine::new(plan4.clone(), Arc::new(SimBackend));
        engine.run(chain_pieces);
    });
    let actions4 = (64 + 2) * chain_pieces * 4;
    let per_action4 = t4.mean_secs / actions4 as f64;
    tab.row(&["per-action overhead (4 queue threads)".into(), fmt::secs(per_action4)]);
    json.push_str(&format!(
        "  \"protocol\": {{\"per_action_ns\": {:.0}, \"per_action_ns_4threads\": {:.0}}},\n",
        per_action * 1e9,
        per_action4 * 1e9
    ));

    // 4. blocked-GEMM throughput on one GPT-shaped matmul — the number
    // `CostModel::calibrated` reads (`gemm.blocked_gflops`) to pin the
    // simulated device's attainable compute rate to this machine; the full
    // scalar-vs-blocked sweep lives in `benches/gemm.rs`.
    let (gm, gk, gn) = if quick { (128, 256, 256) } else { (512, 768, 768) };
    let ga = Rng::new(41).normal_vec(gm * gk, 1.0);
    let gb = Rng::new(43).normal_vec(gk * gn, 1.0);
    let mut gc = vec![0.0; gm * gn];
    let tg = time_n(1, if quick { 2 } else { 5 }, || {
        linalg::gemm(
            gm,
            gk,
            gn,
            MatRef::row_major(&ga, gk),
            MatRef::row_major(&gb, gn),
            &mut gc,
            1,
        )
    });
    let gemm_gflops = 2.0 * (gm * gk * gn) as f64 / tg.mean_secs / 1e9;
    tab.row(&[
        format!("GEMM {gm}x{gk}x{gn} ({}, 1 thread)", linalg::simd_path()),
        format!("{gemm_gflops:.2} GFLOP/s"),
    ]);
    json.push_str(&format!(
        "  \"gemm\": {{\"m\": {gm}, \"k\": {gk}, \"n\": {gn}, \"simd_path\": \"{}\", \
         \"blocked_gflops\": {gemm_gflops:.3}}},\n",
        linalg::simd_path()
    ));

    // 5. compiler latency on a paper-scale plan (GPT 2x8x2 hybrid = 32 dev);
    // skipped under --quick — it dominates the smoke-check budget
    if quick {
        json.push_str("  \"compile\": null\n}\n");
    } else {
        let mut cfg = GptSimConfig::new(2, 8, 2, 64, 2304, 24);
        cfg.devs_per_node = 8;
        let tc = time_n(1, 3, || {
            let (g, loss, upd) = gpt_sim(&cfg);
            let plan = compile(&g, &[loss], &upd, &CompileOptions::default());
            assert!(plan.nodes.len() > 500);
        });
        let (g, loss, upd) = gpt_sim(&cfg);
        let plan = compile(&g, &[loss], &upd, &CompileOptions::default());
        tab.row(&["GPT 32-dev compile latency".into(), fmt::secs(tc.mean_secs)]);
        tab.row(&["  physical ops".into(), plan.nodes.len().to_string()]);
        tab.row(&["  boxing ops".into(), plan.boxing_count().to_string()]);
        tab.row(&["  arena reuse ratio".into(), format!("{:.2}x", plan.mem.reuse_ratio())]);
        json.push_str(&format!(
            "  \"compile\": {{\"secs\": {:.4}, \"phys_ops\": {}, \"transfer_edges\": {}, \"arena_reuse_ratio\": {:.3}}}\n}}\n",
            tc.mean_secs,
            plan.nodes.len(),
            plan.boxing_count(),
            plan.mem.reuse_ratio()
        ));
    }
    tab.print();

    // CI smoke assertions: the pooled path pays only warm-up (a fixed
    // count, amortized to ~0 per step) while the allocating path pays per
    // compute action per step.
    assert!(
        pooled_rep.buffer_allocs < alloc_rep.buffer_allocs / 2,
        "pooled {} allocs vs allocating {} — pooling is not working",
        pooled_rep.buffer_allocs,
        alloc_rep.buffer_allocs
    );
    if !quick {
        assert!(
            per_step_allocs(&pooled_rep) < 1.0,
            "pooled path allocates per step: {:.2}/step",
            per_step_allocs(&pooled_rep)
        );
    }

    std::fs::write("BENCH_actor_micro.json", &json).expect("write BENCH_actor_micro.json");
    println!("\nwrote BENCH_actor_micro.json");
}
