//! Ring-vs-naive collective comparison (§Dist, Table 2's `(p-1)/p` claim
//! made measurable): the rank-local ring all-reduce spreads the gradient
//! combine so the busiest member carries `2(p-1)/p · |T|`, while the naive
//! central path (every shard to one boxing actor, every result back out —
//! what a multi-rank job did before the ring collectives landed) funnels
//! `2(p-1) · |T|` through one member. Wall time here is host-copy dominated
//! (one process), so the bytes columns are the load-bearing result.

use oneflow::bench::Table;
use oneflow::boxing::{apply_boxing_ranked, RankedBoxing};
use oneflow::comm::CollectiveHub;
use oneflow::sbp::{NdSbp, B, P};
use oneflow::tensor::ops::add_n;
use oneflow::tensor::{DType, Tensor};
use oneflow::util::{fmt, Rng};
use std::time::{Duration, Instant};

/// Naive central all-reduce: every member ships its shard to member 0,
/// member 0 reduces and ships the result back to every member.
fn naive_allreduce(shards: &[Tensor]) -> (Vec<Tensor>, f64) {
    let p = shards.len();
    let refs: Vec<&Tensor> = shards.iter().collect();
    let reduced = add_n(&refs);
    let t_bytes = (reduced.elems() * 4) as f64;
    // (p-1) inbound + (p-1) outbound, all through member 0
    let busiest = 2.0 * (p as f64 - 1.0) * t_bytes;
    ((0..p).map(|_| reduced.clone()).collect(), busiest)
}

fn ring_allreduce(shards: &[Tensor]) -> (Vec<Tensor>, f64) {
    let p = shards.len();
    let hub = CollectiveHub::new();
    let ranks = vec![0usize; p];
    let cx = RankedBoxing {
        hub: &hub,
        transport: None,
        member_rank: &ranks,
        my_rank: 0,
        timeout: Duration::from_secs(10),
    };
    let local: Vec<(usize, Tensor)> = shards.iter().cloned().enumerate().collect();
    let res = apply_boxing_ranked(
        &cx,
        1,
        0,
        local,
        &NdSbp::d1(P),
        &NdSbp::d1(B),
        &[p],
        &shards[0].shape,
    )
    .expect("ring all-reduce");
    let busiest = res.bytes_sent / p as f64; // every member sends the same volume
    (res.shards.into_iter().map(|(_, t)| t).collect(), busiest)
}

fn main() {
    let mut tab = Table::new(
        "Ring vs naive all-reduce (gradient combine, busiest-member bytes)",
        &["p", "|T|", "ring busiest", "naive busiest", "ring ms", "naive ms"],
    );
    let mut r = Rng::new(17);
    for &p in &[2usize, 4, 8] {
        // a 1M-element f32 gradient, divisible by every p under test
        let t = Tensor::randn([1024, 1024], DType::F32, 1.0, &mut r);
        let shards: Vec<Tensor> = (0..p)
            .map(|i| if i == 0 { t.clone() } else { Tensor::zeros(t.shape.clone(), t.dtype) })
            .collect();

        let t0 = Instant::now();
        let (ring_out, ring_busiest) = ring_allreduce(&shards);
        let ring_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t1 = Instant::now();
        let (naive_out, naive_busiest) = naive_allreduce(&shards);
        let naive_ms = t1.elapsed().as_secs_f64() * 1e3;

        // sanity: identical results bitwise
        for (a, b) in ring_out.iter().zip(&naive_out) {
            assert_eq!(
                a.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "ring and naive all-reduce disagree"
            );
        }
        let t_bytes = (t.elems() * 4) as f64;
        tab.row(&[
            p.to_string(),
            fmt::bytes(t_bytes),
            fmt::bytes(ring_busiest),
            fmt::bytes(naive_busiest),
            format!("{ring_ms:.1}"),
            format!("{naive_ms:.1}"),
        ]);
    }
    tab.print();
    println!(
        "ring busiest member carries 2(p-1)/p·|T| vs the naive central actor's 2(p-1)·|T| — \
         a p× reduction on the bottleneck link, exactly Table 2's ring model"
    );
}
