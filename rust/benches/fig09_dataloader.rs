//! Fig 9 reproduction: ResNet50 fp16 training throughput under different
//! data loaders. Paper shape: synthetic ≈ OneFlow > DALI > native loaders.

use oneflow::actor::Engine;
use oneflow::baselines::Framework;
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::models::resnet::{resnet50, Loader, ResnetConfig};
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use std::sync::Arc;

fn main() {
    let pieces = 12;
    let mut tab = Table::new(
        "Fig 9 — ResNet50-v1.5 fp16, 1 GPU: images/s by data loader",
        &["loader", "images/s", "vs synthetic"],
    );
    let cases: Vec<(&str, Loader, Framework)> = vec![
        ("synthetic (ideal)", Loader::Synthetic, Framework::OneFlow),
        ("OneFlow pipelined actors", Loader::OneFlow, Framework::OneFlow),
        ("DALI (GPU decode)", Loader::Dali, Framework::NgcPyTorch),
        ("TensorFlow native loader", Loader::Native, Framework::TensorFlow),
        ("PyTorch native loader", Loader::Native, Framework::PyTorch),
    ];
    let mut synth = 0.0;
    for (name, loader, fw) in cases {
        let cfg = ResnetConfig { batch_per_dev: 192, loader, ..Default::default() };
        let pl = Placement::node(0, 1);
        let (g, loss, upd) = resnet50(&cfg, &pl);
        let opts = fw.compile_options();
        let plan = compile(&g, &[loss], &upd, &opts);
        let report = Engine::new(plan, Arc::new(SimBackend)).run(pieces);
        let ips = report.throughput() * cfg.batch_per_dev as f64;
        if synth == 0.0 {
            synth = ips;
        }
        tab.row(&[name.into(), format!("{ips:.0}"), format!("{:.2}x", ips / synth)]);
    }
    tab.print();
    println!("\npaper shape: OneFlow ≈ synthetic; DALI close; native loaders behind");
}
