//! Ablations of DESIGN.md's called-out design choices:
//! 1. SBP selection strategy (greedy vs beam) — plan cost + compile time.
//! 2. Partial-value deferral (§3.3's U×V×W) — with vs without P signatures.
//! 3. Register depth (pipelining) on the data loader.
//! 4. Kernel fusion on/off at fixed everything-else.

use oneflow::actor::Engine;
use oneflow::bench::{time_n, Table};
use oneflow::compiler::{compile, plan_cost, select_sbp, CompileOptions, ScheduleMode, SelectStrategy};
use oneflow::models::resnet::{resnet50, Loader, ResnetConfig};
use oneflow::models::{gpt_sim, GptSimConfig};
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use oneflow::util::fmt;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    // --- 1. selection strategy ---
    let mut tab = Table::new("Ablation — SBP selection strategy (GPT 2x4 hybrid)", &["strategy", "plan cost (model-s)", "select time"]);
    let mut cfg = GptSimConfig::new(2, 4, 1, 16, 1024, 8);
    cfg.devs_per_node = 8;
    let (g, _, _) = gpt_sim(&cfg);
    let cluster = CompileOptions::default().cluster;
    for (name, strat) in [
        ("greedy", SelectStrategy::Greedy),
        ("beam w=4", SelectStrategy::Beam { width: 4 }),
        ("beam w=16", SelectStrategy::Beam { width: 16 }),
    ] {
        let t = time_n(0, 3, || {
            select_sbp(&g, strat, &cluster);
        });
        let sel = select_sbp(&g, strat, &cluster);
        tab.row(&[name.into(), format!("{:.6}", plan_cost(&g, &sel, &cluster)), fmt::secs(t.mean_secs)]);
    }
    tab.print();

    // --- 3. register schedule on the loader ---
    // register depth is no longer a free knob: the scheduling pass derives
    // slot quotas, so the ablation is single-slot vs scheduled registers
    let mut tab = Table::new("Ablation — register schedule (loader pipelining), ResNet50", &["schedule", "images/s"]);
    for (name, schedule) in [
        ("unoverlapped (1 slot)", ScheduleMode::Unoverlapped),
        ("1f1b (scheduled quotas)", ScheduleMode::OneFOneB),
    ] {
        let cfgr = ResnetConfig { batch_per_dev: 192, loader: Loader::OneFlow, ..Default::default() };
        let pl = Placement::node(0, 1);
        let (g, loss, upd) = resnet50(&cfgr, &pl);
        let opts = CompileOptions { schedule, ..Default::default() };
        let plan = compile(&g, &[loss], &upd, &opts);
        let report = Engine::new(plan, Arc::new(SimBackend)).run(8);
        tab.row(&[name.into(), format!("{:.0}", report.throughput() * 192.0)]);
    }
    tab.print();

    // --- 4. fusion on/off ---
    let mut tab = Table::new("Ablation — kernel fusion (ResNet50, 1 GPU)", &["fusion", "images/s"]);
    for fuse in [true, false] {
        let cfgr = ResnetConfig { batch_per_dev: 192, ..Default::default() };
        let pl = Placement::node(0, 1);
        let (g, loss, upd) = resnet50(&cfgr, &pl);
        let opts = CompileOptions { fuse, ..Default::default() };
        let plan = compile(&g, &[loss], &upd, &opts);
        let report = Engine::new(plan, Arc::new(SimBackend)).run(8);
        tab.row(&[if fuse { "on" } else { "off" }.into(), format!("{:.0}", report.throughput() * 192.0)]);
    }
    tab.print();
    let _ = HashMap::<u8, u8>::new();
}
