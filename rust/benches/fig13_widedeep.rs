//! Fig 13 reproduction: Wide & Deep vs HugeCTR — per-iteration latency and
//! per-device memory as vocabulary grows. Paper shape: OneFlow lower latency
//! and memory; HugeCTR OOMs past 51.2M ids (16 GB devices).

use oneflow::actor::Engine;
use oneflow::baselines::Framework;
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::exec::DeviceModel;
use oneflow::models::wide_deep::{table_bytes, wide_deep};
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use oneflow::util::fmt;
use std::sync::Arc;

fn main() {
    let ndev = 8;
    let pl = Placement::node(0, ndev);
    let mut tab = Table::new(
        "Fig 13 — Wide&Deep on 8 GPUs vs vocabulary size",
        &["vocab (M)", "OneFlow latency", "OneFlow mem/GPU", "HugeCTR latency", "HugeCTR mem/GPU"],
    );
    for vocab_m in [3.2f64, 6.4, 12.8, 25.6, 51.2, 102.4] {
        let vocab = (vocab_m * 1e6) as usize;
        let (g, loss, upd) = wide_deep(vocab, 512, &pl);
        let plan = compile(&g, &[loss], &upd, &Framework::OneFlow.compile_options());
        let mem = plan.peak_device_memory();
        let report = Engine::new(plan, Arc::new(SimBackend)).run(4);
        let lat = report.makespan / 4.0;
        // HugeCTR profile: same plan structure, unfused + dispatcher overhead
        let (g2, loss2, upd2) = wide_deep(vocab, 512, &pl);
        let plan2 = compile(&g2, &[loss2], &upd2, &Framework::HugeCtr.compile_options());
        let report2 = Engine::new(plan2, Arc::new(SimBackend)).run(4);
        let hugectr_lat = report2.makespan / 4.0;

        // HugeCTR: sharded table but replicated fp32 optimizer copies for the
        // dense part plus per-device all-gather buffers for the full batch's
        // embeddings (its "localized slot" design), ~2x working buffers.
        let hugectr_mem = table_bytes(vocab, 2.0) / ndev as f64 // table + states
            + 512.0 * 26.0 * 16.0 * 4.0 * ndev as f64 // gather buffers
            + 0.4e9; // dense replica + workspace
        let cap = DeviceModel::v100().mem_bytes as f64;
        let oom = hugectr_mem > cap;
        tab.row(&[
            format!("{vocab_m}"),
            fmt::secs(lat),
            fmt::bytes(mem),
            if oom { "OOM".into() } else { fmt::secs(hugectr_lat) },
            if oom { format!("OOM ({})", fmt::bytes(hugectr_mem)) } else { fmt::bytes(hugectr_mem) },
        ]);
        // compile-time check mirrors the paper: OneFlow survives 102.4M
        assert!(mem < cap, "OneFlow OOM at {vocab_m}M ids");
    }
    tab.print();
    println!("\npaper shape: OneFlow lower latency + memory; HugeCTR OOM beyond 51.2M ids");
}
