//! Checkpoint-plane costs (§Fault): what a snapshot costs to take, write,
//! and restore, and what the segmented session driver costs per step
//! relative to a plain uninterrupted run. Two acceptance numbers ride
//! along: steady-state training allocates **zero** buffers per step with
//! checkpointing off (the pool claim survives the capture machinery), and
//! re-running through the session driver stays bitwise-identical to the
//! plain run (DESIGN.md invariant 14). Results go to
//! `BENCH_checkpoint.json`; `--quick` shrinks the run for CI.

use oneflow::actor::{DataSource, Engine, FnSource, RunOptions};
use oneflow::bench::{time_n, Table};
use oneflow::checkpoint::{restore, run_session, snapshot, SessionOptions, Snapshot};
use oneflow::comm::{Loopback, Transport};
use oneflow::compiler::{compile, CompileOptions, InputBinding, PhysPlan};
use oneflow::config::Args;
use oneflow::data::SyntheticCorpus;
use oneflow::models::{gpt_pipeline_real, GptPipelineConfig};
use oneflow::runtime::NativeBackend;
use oneflow::tensor::Tensor;
use oneflow::util::fmt;
use std::sync::Arc;
use std::time::Duration;

fn cfg(quick: bool) -> GptPipelineConfig {
    GptPipelineConfig {
        stages: 2,
        vocab: 32,
        hidden: if quick { 16 } else { 32 },
        ff: if quick { 32 } else { 64 },
        blocks_per_stage: 1,
        rows: 32,
        lr: 0.2,
        microbatches: 1,
    }
}

fn build(quick: bool) -> PhysPlan {
    let (g, loss, upd) = gpt_pipeline_real(&cfg(quick));
    compile(&g, &[loss], &upd, &CompileOptions::default())
}

fn source(quick: bool) -> Arc<dyn DataSource> {
    let c = cfg(quick);
    let corpus = Arc::new(SyntheticCorpus::new(2048, c.vocab, 17));
    let rows = c.rows;
    Arc::new(FnSource(move |b: &InputBinding, piece: usize| {
        let (ids, labels) = corpus.batch(piece, 1, rows);
        match b.name.as_str() {
            "ids" => Tensor::new([rows], oneflow::tensor::DType::I32, ids.data),
            "labels" => Tensor::new([rows], oneflow::tensor::DType::I32, labels.data),
            _ => Tensor::full(b.shape.clone(), b.dtype, 1.0),
        }
    }))
}

fn plain_run(quick: bool, pieces: usize) -> oneflow::actor::RunReport {
    Engine::new(build(quick), Arc::new(NativeBackend))
        .with_source(source(quick))
        .run_with(RunOptions { pieces, timeout: Some(Duration::from_secs(120)) })
        .expect("plain run")
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let (p_short, p_long) = if quick { (8usize, 16usize) } else { (16usize, 48usize) };
    let every = 4usize;
    let dir = std::env::temp_dir().join(format!("ofck-bench-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut tab = Table::new("Checkpoint plane", &["metric", "value"]);

    // 1. zero-allocation claim: buffer allocs are warm-up only, so the
    // delta between a short and a long run — the steady-state pieces — must
    // allocate nothing. Checkpointing off; this is the baseline invariant.
    let short = plain_run(quick, p_short);
    let long = plain_run(quick, p_long);
    let steady_allocs = long.buffer_allocs as i64 - short.buffer_allocs as i64;
    let steady_pieces = (p_long - p_short) as f64;
    let allocs_per_step = steady_allocs as f64 / steady_pieces;
    tab.row(&["steady-state buffer allocs/step (ckpt off)".into(), format!("{allocs_per_step:.3}")]);
    assert_eq!(
        steady_allocs, 0,
        "steady-state training must not allocate: {steady_allocs} pool misses over \
         {steady_pieces} pieces"
    );

    // 2. per-step cost: the plain engine vs the segmented session driver
    // (engine rebuild + capture + snapshot every `every` pieces)
    let t_plain = time_n(1, if quick { 2 } else { 3 }, || {
        let _ = plain_run(quick, p_short);
    });
    let step_plain = t_plain.mean_secs / p_short as f64;

    let connect = |_e: u32, _r: u64| -> oneflow::Result<Arc<dyn Transport>> {
        Ok(Arc::new(Loopback::default()))
    };
    let session = |pieces: usize| {
        run_session(
            Arc::new(build(quick)),
            Arc::new(NativeBackend),
            source(quick),
            &connect,
            &SessionOptions {
                pieces,
                every,
                dir: dir.clone(),
                timeout: Some(Duration::from_secs(120)),
                ..Default::default()
            },
            |_, _, _| {},
        )
        .expect("checkpointed session")
    };
    let t_sess = time_n(1, if quick { 2 } else { 3 }, || {
        let _ = session(p_short);
    });
    let step_sess = t_sess.mean_secs / p_short as f64;
    let overhead = step_sess / step_plain - 1.0;
    tab.row(&["step (plain engine)".into(), fmt::secs(step_plain)]);
    tab.row(&[format!("step (session, snapshot every {every})"), fmt::secs(step_sess)]);
    tab.row(&["session overhead".into(), format!("{:.1}%", overhead * 100.0)]);

    // 3. invariant 14 smoke: the session's losses match the plain run's
    // bitwise (the full matrix lives in tests/checkpoint.rs)
    let plan = build(quick);
    let tid = plan.fetches[0].tensor;
    let want: Vec<Vec<u32>> = short.fetched[&tid]
        .iter()
        .map(|t| t.data.iter().map(|x| x.to_bits()).collect())
        .collect();
    let rep = session(p_short);
    for (fetch, piece, t) in &rep.losses {
        assert_eq!(*fetch, tid);
        let got: Vec<u32> = t.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(
            got, want[*piece as usize],
            "session loss at piece {piece} diverged from the plain run"
        );
    }

    // 4. snapshot encode/write and load/restore costs, plus the bytes a
    // boundary costs on disk
    let captured = Engine::new(build(quick), Arc::new(NativeBackend))
        .with_source(source(quick))
        .with_capture()
        .run_with(RunOptions { pieces: every, timeout: Some(Duration::from_secs(120)) })
        .expect("capture run");
    let snap =
        snapshot(&plan, 0, 1, every as u64, &captured.var_state).expect("snapshot from capture");
    let snap_bytes = snap.encode().len();
    let t_write = time_n(1, if quick { 5 } else { 20 }, || {
        snap.write(&dir).expect("snapshot write");
    });
    let path = oneflow::checkpoint::snapshot_path(&dir, 0, every as u64);
    let t_load = time_n(1, if quick { 5 } else { 20 }, || {
        let s = Snapshot::load(&path).expect("snapshot load");
        let _ = restore(&plan, &s).expect("restore");
    });
    tab.row(&["snapshot size".into(), fmt::bytes(snap_bytes as f64)]);
    tab.row(&["snapshot encode+write".into(), fmt::secs(t_write.mean_secs)]);
    tab.row(&["snapshot load+restore".into(), fmt::secs(t_load.mean_secs)]);

    tab.print();

    let json = format!(
        "{{\n  \"bench\": \"checkpoint\",\n  \"quick\": {quick},\n  \"pieces\": {p_short},\n  \
         \"every\": {every},\n  \"steady_allocs_per_step\": {allocs_per_step:.3},\n  \
         \"step_plain_secs\": {step_plain:.6},\n  \"step_session_secs\": {step_sess:.6},\n  \
         \"session_overhead_frac\": {overhead:.4},\n  \"snapshot_bytes\": {snap_bytes},\n  \
         \"snapshot_write_secs\": {:.6},\n  \"restore_load_secs\": {:.6}\n}}\n",
        t_write.mean_secs, t_load.mean_secs
    );
    std::fs::write("BENCH_checkpoint.json", &json).expect("write BENCH_checkpoint.json");
    println!("\nwrote BENCH_checkpoint.json");
    let _ = std::fs::remove_dir_all(&dir);
}
