//! Fig 10 reproduction: data-parallel scaling of ResNet50 and BERT-base on
//! 1–32 simulated V100s, fp32 and fp16, across framework profiles.
//! Paper shape: OneFlow > NGC-optimized > stock frameworks; near-linear
//! scaling for ResNet; fp16 widens the gap (comm-bound).

use oneflow::actor::Engine;
use oneflow::baselines::{fig10_frameworks, Framework};
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::models::bert_base;
use oneflow::models::resnet::{resnet50, Loader, ResnetConfig};
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use oneflow::tensor::DType;
use std::sync::Arc;

fn placement(n: usize) -> Placement {
    Placement::flat(n.div_ceil(8), n.min(8))
}

fn run_resnet(fw: Framework, ndev: usize, dtype: DType) -> f64 {
    // synthetic input for every framework: Fig 10 isolates the training
    // loop; loader effects are Fig 9's subject (the paper does the same —
    // its Fig 10 runs use each framework's tuned loader at full speed).
    let cfg = ResnetConfig {
        batch_per_dev: if dtype == DType::F16 { 192 } else { 128 },
        dtype,
        loader: Loader::Synthetic,
        ..Default::default()
    };
    let (g, loss, upd) = resnet50(&cfg, &placement(ndev));
    let plan = compile(&g, &[loss], &upd, &fw.compile_options());
    let report = Engine::new(plan, Arc::new(SimBackend)).run(6);
    report.throughput() * (cfg.batch_per_dev * ndev) as f64
}

fn run_bert(fw: Framework, ndev: usize, dtype: DType) -> f64 {
    let (g, loss, upd) = bert_base(ndev, if dtype == DType::F16 { 64 } else { 32 }, dtype);
    let plan = compile(&g, &[loss], &upd, &fw.compile_options());
    let report = Engine::new(plan, Arc::new(SimBackend)).run(4);
    report.throughput() * (if dtype == DType::F16 { 64 } else { 32 } * ndev) as f64
}

fn main() {
    for (model, runner) in [
        ("ResNet50", run_resnet as fn(Framework, usize, DType) -> f64),
        ("BERT-base", run_bert as fn(Framework, usize, DType) -> f64),
    ] {
        for dtype in [DType::F32, DType::F16] {
            let mut tab = Table::new(
                format!("Fig 10 — {model} data parallelism, {dtype} (samples/s)"),
                &["framework", "1 GPU", "8 GPUs", "16 GPUs", "32 GPUs", "scale eff @32"],
            );
            for fw in fig10_frameworks() {
                let t: Vec<f64> = [1usize, 8, 16, 32].iter().map(|&n| runner(fw, n, dtype)).collect();
                tab.row(&[
                    fw.name().into(),
                    format!("{:.0}", t[0]),
                    format!("{:.0}", t[1]),
                    format!("{:.0}", t[2]),
                    format!("{:.0}", t[3]),
                    format!("{:.0}%", 100.0 * t[3] / (t[0] * 32.0)),
                ]);
            }
            tab.print();
        }
    }
    println!("\npaper shape: OneFlow ahead of NGC, NGC ahead of stock; fp16 widens gaps");
}
