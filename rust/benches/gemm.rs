//! GEMM throughput (§Perf): GFLOP/s for GPT-shaped matmuls through the
//! scalar reference, the blocked/packed/SIMD kernel single-threaded, and
//! the blocked kernel with `--intraop` fan-out — the before/after for the
//! `linalg` layer (ROADMAP "[perf] Real GEMM"). Every timed pair is also
//! checked **bitwise-equal** (DESIGN.md invariant 13) so the speed and the
//! determinism claim are asserted by the same binary. Results go to
//! `BENCH_gemm.json`; `--quick` shrinks shapes to a CI smoke check.

use oneflow::bench::{time_n, Table};
use oneflow::config::Args;
use oneflow::linalg::{self, MatRef};
use oneflow::util::Rng;

struct Shape {
    label: &'static str,
    m: usize,
    k: usize,
    n: usize,
}

/// GPT-small per-microbatch GEMMs (seq 512, hidden 768, ff 3072).
const FULL: &[Shape] = &[
    Shape { label: "attn qkv   512x768x2304", m: 512, k: 768, n: 2304 },
    Shape { label: "attn out   512x768x768", m: 512, k: 768, n: 768 },
    Shape { label: "ff up      512x768x3072", m: 512, k: 768, n: 3072 },
    Shape { label: "ff down    512x3072x768", m: 512, k: 3072, n: 768 },
];

/// Same aspect ratios, shrunk for the CI smoke leg.
const QUICK: &[Shape] = &[
    Shape { label: "attn out   128x192x192", m: 128, k: 192, n: 192 },
    Shape { label: "ff up      128x192x768", m: 128, k: 192, n: 768 },
];

fn gflops(m: usize, k: usize, n: usize, secs: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 / secs / 1e9
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let shapes = if quick { QUICK } else { FULL };
    let iters = if quick { 2 } else { 3 };
    let intraop = 4;

    let mut tab = Table::new(
        format!("GEMM GFLOP/s (micro-kernel path: {})", linalg::simd_path()),
        &["shape", "scalar", "blocked x1", &format!("blocked x{intraop}")],
    );
    let mut json = String::from("{\n  \"bench\": \"gemm\",\n");
    json.push_str(&format!(
        "  \"quick\": {quick},\n  \"simd_path\": \"{}\",\n  \"intraop\": {intraop},\n  \"shapes\": [\n",
        linalg::simd_path()
    ));

    let mut r = Rng::new(42);
    let (mut speedup_min, mut blocked1_sum) = (f64::INFINITY, 0.0);
    for (si, s) in shapes.iter().enumerate() {
        let (m, k, n) = (s.m, s.k, s.n);
        let a = r.normal_vec(m * k, 1.0);
        let b = r.normal_vec(k * n, 1.0);
        let (av, bv) = (MatRef::row_major(&a, k), MatRef::row_major(&b, n));
        let mut want = vec![0.0; m * n];
        let mut got = vec![0.0; m * n];

        let t_ref = time_n(1, iters, || linalg::reference_gemm(m, k, n, av, bv, &mut want));
        let t_blk = time_n(1, iters, || linalg::gemm(m, k, n, av, bv, &mut got, 1));
        // invariant 13: the timed kernels must agree bitwise, every shape
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "blocked != reference bitwise on {}",
            s.label
        );
        let t_par = time_n(1, iters, || linalg::gemm(m, k, n, av, bv, &mut got, intraop));

        let (g_ref, g_blk, g_par) = (
            gflops(m, k, n, t_ref.mean_secs),
            gflops(m, k, n, t_blk.mean_secs),
            gflops(m, k, n, t_par.mean_secs),
        );
        speedup_min = speedup_min.min(g_blk / g_ref);
        blocked1_sum += g_blk;
        tab.row(&[
            s.label.into(),
            format!("{g_ref:.2}"),
            format!("{g_blk:.2}"),
            format!("{g_par:.2}"),
        ]);
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"m\": {m}, \"k\": {k}, \"n\": {n}, \
             \"scalar_gflops\": {g_ref:.3}, \"blocked_gflops\": {g_blk:.3}, \
             \"blocked_intraop_gflops\": {g_par:.3}}}{}\n",
            s.label.trim_end(),
            if si + 1 < shapes.len() { "," } else { "" }
        ));
    }
    let blocked_mean = blocked1_sum / shapes.len() as f64;
    json.push_str(&format!(
        "  ],\n  \"min_speedup_vs_scalar\": {speedup_min:.3},\n  \
         \"blocked_gflops_mean\": {blocked_mean:.3}\n}}\n"
    ));
    tab.print();
    println!("\nmin blocked/scalar speedup: {speedup_min:.2}x");

    // CI smoke: the blocked kernel must never lose to the scalar loop. The
    // margin is generous (timer noise on shared runners), the real ≥4x
    // single-thread claim is tracked by the committed full-run snapshot.
    assert!(
        speedup_min >= 0.9,
        "blocked GEMM slower than the scalar reference: {speedup_min:.2}x"
    );

    std::fs::write("BENCH_gemm.json", &json).expect("write BENCH_gemm.json");
    println!("wrote BENCH_gemm.json");
}
