//! Auto-parallelism bench (ISSUE 8): search the `stages × dp × tp` lattice
//! of a device world for the hybrid GPT, then *simulate* every surviving
//! candidate and compare the search's predicted ranking against the
//! virtual-time measurement. Writes `BENCH_autoparallel.json` with the
//! frontier size, search wall time, and the winner-vs-baseline makespan
//! ratio (baseline = the hand-picked default grid of the same world).
//! `--quick` shrinks the world for CI.

use oneflow::actor::Engine;
use oneflow::bench::Table;
use oneflow::compiler::{
    compile, search, CompileOptions, ParallelConfig, ScheduleMode, SearchSpace,
};
use oneflow::config::Args;
use oneflow::exec::CostModel;
use oneflow::models::{gpt_hybrid_auto, GptModelSpec};
use oneflow::runtime::SimBackend;
use oneflow::util::fmt;
use std::sync::Arc;
use std::time::Instant;

/// Compile one config's plan and run it on the simulated cluster; returns
/// the measured virtual makespan per piece.
fn simulate(spec: &GptModelSpec, pc: &ParallelConfig, cost: &CostModel, pieces: usize) -> f64 {
    let (g, loss, upd) = gpt_hybrid_auto(spec, pc).expect("feasible config");
    let opts = CompileOptions {
        schedule: pc.schedule,
        microbatches: pc.microbatches,
        cluster: cost.cluster,
        parallel: Some(*pc),
        ..Default::default()
    };
    let plan = compile(&g, &[loss], &upd, &opts);
    let report = Engine::new(plan, Arc::new(SimBackend)).run(pieces);
    report.makespan / pieces as f64
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let (nodes, dpn) = if quick { (2, 2) } else { (4, 2) };
    let pieces = 8;
    let space = SearchSpace {
        nodes,
        devs_per_node: dpn,
        microbatches: 4,
        schedule: ScheduleMode::OneFOneB,
    };
    let spec = GptModelSpec::default();
    let cost = CostModel::paper_testbed();
    let base = CompileOptions::default();

    let t0 = Instant::now();
    let frontier = search::search(&space, &cost, &base, |pc| gpt_hybrid_auto(&spec, pc));
    let search_secs = t0.elapsed().as_secs_f64();

    frontier.table().print();
    println!();
    let winner = frontier.winner().expect("search found no feasible config").clone();

    // the grid a user would have hand-picked for this world: the default
    // 2-stage dp×tp hybrid, sized to fill nodes×dpn devices
    let world = space.world_devices();
    let baseline = ParallelConfig {
        stages: 2,
        dp: world / 4,
        tp: 2,
        devs_per_node: dpn,
        microbatches: space.microbatches,
        schedule: space.schedule,
    };
    assert_eq!(baseline.n_devices(), world, "baseline must fill the world");
    let baseline_pred = frontier
        .candidates
        .iter()
        .find(|c| c.config == baseline)
        .map(|c| c.predicted.makespan)
        .expect("hand-picked baseline grid must be a legal candidate");

    // measure every survivor on the simulated cluster and compare orderings
    let mut tab = Table::new(
        &format!("auto-parallel: predicted vs simulated ({world} devices)"),
        &["config", "predicted/piece", "simulated/piece", "pred/sim"],
    );
    let mut measured: Vec<(ParallelConfig, f64, f64)> = Vec::new();
    for c in &frontier.candidates {
        let sim = simulate(&spec, &c.config, &cost, pieces);
        tab.row(&[
            c.config.label(),
            fmt::secs(c.predicted.makespan),
            fmt::secs(sim),
            format!("{:.2}", c.predicted.makespan / sim),
        ]);
        measured.push((c.config, c.predicted.makespan, sim));
    }
    tab.print();

    // rank agreement: fraction of candidate pairs the prediction orders the
    // same way the simulation does (1.0 = identical ranking)
    let mut concordant = 0usize;
    let mut pairs = 0usize;
    for i in 0..measured.len() {
        for j in i + 1..measured.len() {
            pairs += 1;
            let pred = measured[i].1 <= measured[j].1;
            let sim = measured[i].2 <= measured[j].2;
            if pred == sim {
                concordant += 1;
            }
        }
    }
    let rank_agreement = if pairs > 0 { concordant as f64 / pairs as f64 } else { 1.0 };

    let winner_sim = measured[0].2;
    let baseline_sim = measured
        .iter()
        .find(|(pc, _, _)| *pc == baseline)
        .map(|(_, _, s)| *s)
        .unwrap();
    let ratio_pred = winner.predicted.makespan / baseline_pred;
    let ratio_sim = winner_sim / baseline_sim;
    println!(
        "\nsearch: {} survivors, {} pruned, {:.3}s wall",
        frontier.candidates.len(),
        frontier.pruned.len(),
        search_secs
    );
    println!(
        "winner {} vs hand-picked {}: predicted {:.3}x, simulated {:.3}x, rank agreement {:.2}",
        winner.config.label(),
        baseline.label(),
        ratio_pred,
        ratio_sim,
        rank_agreement
    );

    // acceptance: the searched winner is predicted no slower than the
    // hand-picked baseline, and the simulation confirms it (5% tolerance
    // for cost-model error)
    assert!(
        winner.predicted.makespan <= baseline_pred,
        "winner predicted {} slower than baseline {}",
        winner.predicted.makespan,
        baseline_pred
    );
    assert!(
        ratio_sim <= 1.05,
        "searched winner simulated {:.3}x the hand-picked baseline",
        ratio_sim
    );
    assert!(
        rank_agreement >= 0.5,
        "predicted ranking mostly disagrees with simulation ({rank_agreement:.2})"
    );

    let json = format!(
        "{{\n  \"bench\": \"autoparallel\",\n  \"quick\": {quick},\n  \"world\": {world},\n  \
         \"frontier_size\": {},\n  \"pruned\": {},\n  \"search_secs\": {search_secs:.6},\n  \
         \"winner\": \"{}\",\n  \"baseline\": \"{}\",\n  \
         \"winner_predicted_secs\": {:.6e},\n  \"winner_simulated_secs\": {winner_sim:.6e},\n  \
         \"baseline_predicted_secs\": {baseline_pred:.6e},\n  \
         \"baseline_simulated_secs\": {baseline_sim:.6e},\n  \
         \"winner_vs_baseline_predicted\": {ratio_pred:.4},\n  \
         \"winner_vs_baseline_simulated\": {ratio_sim:.4},\n  \
         \"rank_agreement\": {rank_agreement:.4}\n}}\n",
        frontier.candidates.len(),
        frontier.pruned.len(),
        winner.config.label(),
        baseline.label(),
        winner.predicted.makespan,
    );
    std::fs::write("BENCH_autoparallel.json", &json).expect("write BENCH_autoparallel.json");
    println!("\nwrote BENCH_autoparallel.json");
}
