//! Transport-plane microbenchmarks (§Dist): wire-codec throughput, and
//! message round-trip latency / one-way bandwidth for the in-process bus
//! hop (what `loopback` traffic costs) vs the TCP transport on localhost —
//! the BENCH trajectory's first communication numbers.

use oneflow::actor::{ActorAddr, Envelope, Msg};
use oneflow::bench::Table;
use oneflow::comm::{tcp_local_world, wire, Transport};
use oneflow::compiler::RegId;
use oneflow::exec::QueueKind;
use oneflow::tensor::Tensor;
use oneflow::util::fmt;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

const PING: u8 = 0;
const STOP: u8 = 1;
const BULK: u8 = 2;
const BULK_ACK: u8 = 3;

fn tcp_pair() -> (Arc<dyn Transport>, Arc<dyn Transport>) {
    let mut w = tcp_local_world(2).expect("rendezvous");
    let t1 = w.pop().expect("rank 1");
    let t0 = w.pop().expect("rank 0");
    (t0, t1)
}

fn main() {
    let mut tab = Table::new("Transport plane microbenchmarks", &["metric", "value"]);

    // 1. wire codec: a Req envelope carrying a 16k-element f32 activation
    let payload = Tensor::f32([64, 256], (0..64 * 256).map(|i| i as f32 * 0.5).collect());
    let env = Envelope {
        to: ActorAddr::new(1, QueueKind::Compute, 0, 7),
        msg: Msg::Req { reg: RegId(3), piece: 0, data: Some(Arc::new(vec![payload])), ts: 0.5 },
    };
    let frame = wire::encode_envelope(&env);
    let bytes_per = frame.len() as f64;
    let iters = 200;
    let t0 = Instant::now();
    for _ in 0..iters {
        let f = wire::encode_envelope(&env);
        let _ = wire::decode(&f).expect("decode");
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    tab.row(&["wire frame (64 KiB payload)".into(), fmt::bytes(bytes_per)]);
    tab.row(&["wire encode+decode".into(), fmt::secs(per)]);
    tab.row(&["wire codec throughput".into(), format!("{}/s", fmt::bytes(bytes_per / per))]);

    // 2. the in-process bus hop (what loopback-world traffic costs): a
    // cross-thread mpsc round trip of a small frame
    let (ping_tx, ping_rx) = mpsc::channel::<Vec<u8>>();
    let (pong_tx, pong_rx) = mpsc::channel::<Vec<u8>>();
    let echo = std::thread::spawn(move || {
        while let Ok(f) = ping_rx.recv() {
            if f.first() == Some(&STOP) {
                break;
            }
            let _ = pong_tx.send(f);
        }
    });
    let small = vec![PING; 64];
    let rounds = 5000;
    let t0 = Instant::now();
    for _ in 0..rounds {
        ping_tx.send(small.clone()).expect("ping");
        pong_rx.recv().expect("pong");
    }
    let bus_rt = t0.elapsed().as_secs_f64() / rounds as f64;
    ping_tx.send(vec![STOP]).expect("stop");
    echo.join().expect("echo thread");
    tab.row(&["bus hop round trip (64 B)".into(), fmt::secs(bus_rt)]);

    // 3. tcp on localhost: round-trip latency + one-way bulk bandwidth
    let (a, b) = tcp_pair();
    let bulk_n = 64usize;
    let responder = std::thread::spawn(move || {
        let mut bulk_seen = 0usize;
        loop {
            match b.recv_timeout(Duration::from_secs(10)) {
                Ok(Some((_, f))) => match f.first() {
                    Some(&PING) => b.send(0, f).expect("echo"),
                    Some(&BULK) => {
                        bulk_seen += 1;
                        if bulk_seen == bulk_n {
                            b.send(0, vec![BULK_ACK]).expect("bulk ack");
                        }
                    }
                    Some(&STOP) | None => break,
                    _ => {}
                },
                Ok(None) | Err(_) => break,
            }
        }
    });
    let rounds = 1000;
    let t0 = Instant::now();
    for _ in 0..rounds {
        a.send(1, small.clone()).expect("ping");
        let (_, f) = a.recv_timeout(Duration::from_secs(10)).expect("pong").expect("pong frame");
        assert_eq!(f.first(), Some(&PING));
    }
    let tcp_rt = t0.elapsed().as_secs_f64() / rounds as f64;
    tab.row(&["tcp round trip (64 B, localhost)".into(), fmt::secs(tcp_rt)]);
    tab.row(&["tcp vs bus hop".into(), format!("{:.1}x", tcp_rt / bus_rt)]);

    let bulk = vec![BULK; 256 * 1024];
    let t0 = Instant::now();
    for _ in 0..bulk_n {
        a.send(1, bulk.clone()).expect("bulk send");
    }
    let (_, ack) = a.recv_timeout(Duration::from_secs(30)).expect("ack").expect("ack frame");
    assert_eq!(ack.first(), Some(&BULK_ACK));
    let secs = t0.elapsed().as_secs_f64();
    let moved = (bulk.len() * bulk_n) as f64;
    tab.row(&["tcp one-way bandwidth (256 KiB frames)".into(), format!("{}/s", fmt::bytes(moved / secs))]);
    a.send(1, vec![STOP]).expect("stop");
    responder.join().expect("responder");

    tab.print();
}
