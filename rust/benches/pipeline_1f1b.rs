//! Pipeline-schedule bench (paper §4.3 / §6.5): virtual-time makespan of a
//! balanced p-stage pipeline under the compiled 1F1B register quotas vs the
//! single-slot unoverlapped baseline, across stage counts. Asserts the
//! measured 1F1B bubble matches the ideal `(p-1)/(m+p-1)` and writes
//! `BENCH_pipeline_1f1b.json`; `--quick` shrinks the sweep for CI.
//!
//! Each point also reruns 1F1B with the actor-event recorder on: the traced
//! makespan must equal the untraced one bit for bit (DESIGN.md invariant
//! 11), and the bubble derived from the merged timeline
//! ([`oneflow::metrics::trace_summary`]) must sit on the same analytic
//! curve. The last point's summary is written to `TRACE_summary.json`.

use oneflow::actor::Engine;
use oneflow::bench::Table;
use oneflow::compiler::{compile, CompileOptions, PhysPlan, ScheduleMode};
use oneflow::config::Args;
use oneflow::exec::{CostSpec, QueueKind};
use oneflow::graph::{LogicalGraph, OpKind, TensorId};
use oneflow::metrics;
use oneflow::pipeline::bubble_fraction;
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use oneflow::tensor::DType;
use oneflow::util::fmt;
use std::collections::HashMap;
use std::sync::Arc;

/// A balanced `p`-stage chain of equal-flops ops, one stage per node, fed
/// by a free host-side source (same shape as `tests/schedule.rs`).
fn stage_chain(p: usize, flops: f64) -> (LogicalGraph, TensorId) {
    let mut g = LogicalGraph::new();
    let mut t = g.add1(
        "src",
        OpKind::Flops {
            name: "src".into(),
            out: [4, 4].into(),
            dtype: DType::F32,
            cost: CostSpec { flops: 0.0, read_bytes: 0.0, write_bytes: 0.0, queue: QueueKind::HostCpu },
            split_axes: vec![0],
            param_bytes: 0.0,
        },
        &[],
        Placement::node(0, 1),
    );
    for s in 0..p {
        t = g.add1(
            format!("stage{s}"),
            OpKind::Flops {
                name: format!("stage{s}"),
                out: [4, 4].into(),
                dtype: DType::F32,
                cost: CostSpec::compute(flops, 0.0, 0.0),
                split_axes: vec![0],
                param_bytes: 0.0,
            },
            &[t],
            Placement::node(s, 1),
        );
    }
    (g, t)
}

fn build(p: usize, m: usize, schedule: ScheduleMode) -> PhysPlan {
    let (g, y) = stage_chain(p, 2e10);
    let opts = CompileOptions { microbatches: m, fuse: false, schedule, ..Default::default() };
    compile(&g, &[y], &HashMap::new(), &opts)
}

fn main() {
    let args = Args::from_env();
    let quick = args.flag("quick");
    let m = 8usize;
    let stage_counts: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };

    let mut tab = Table::new(
        "Pipeline schedule — makespan vs stage count (balanced chain, M=8 microbatches)",
        &[
            "stages",
            "unoverlapped",
            "1f1b",
            "speedup",
            "bubble (measured)",
            "bubble (trace)",
            "bubble (ideal)",
        ],
    );
    let mut rows = Vec::new();
    let mut last_summary = None;
    for &p in stage_counts {
        let serial = Engine::new(build(p, m, ScheduleMode::Unoverlapped), Arc::new(SimBackend)).run(m);
        let overlapped = Engine::new(build(p, m, ScheduleMode::OneFOneB), Arc::new(SimBackend)).run(m);
        let teng =
            Engine::new(build(p, m, ScheduleMode::OneFOneB), Arc::new(SimBackend)).with_trace();
        let traced = teng.run(m);
        let busy: f64 = overlapped
            .queue_busy
            .iter()
            .filter(|(k, _)| k.queue == QueueKind::Compute)
            .map(|(_, v)| *v)
            .sum();
        let measured = 1.0 - busy / (p as f64 * overlapped.makespan);
        let ideal = bubble_fraction(p, m);
        let speedup = serial.makespan / overlapped.makespan;

        // invariant 11: tracing is schedule-transparent — the traced run's
        // virtual makespan equals the untraced one bit for bit
        assert_eq!(
            traced.makespan.to_bits(),
            overlapped.makespan.to_bits(),
            "p={p}: tracing perturbed the makespan"
        );
        let trace = traced.trace.as_ref().expect("traced run carries a timeline");
        let summary = metrics::trace_summary(trace, teng.plan());
        assert!(
            (summary.bubble_measured - ideal).abs() < 0.05,
            "p={p}: trace-derived bubble {:.4} off the ideal {ideal:.4}",
            summary.bubble_measured
        );
        let bubble_trace = summary.bubble_measured;

        tab.row(&[
            p.to_string(),
            fmt::secs(serial.makespan),
            fmt::secs(overlapped.makespan),
            format!("{speedup:.2}x"),
            format!("{measured:.4}"),
            format!("{bubble_trace:.4}"),
            format!("{ideal:.4}"),
        ]);
        rows.push(format!(
            "    {{\"stages\": {p}, \"microbatches\": {m}, \
             \"makespan_unoverlapped\": {:.6e}, \"makespan_1f1b\": {:.6e}, \
             \"speedup\": {speedup:.4}, \"bubble_measured\": {measured:.4}, \
             \"bubble_trace\": {bubble_trace:.4}, \"bubble_ideal\": {ideal:.4}}}",
            serial.makespan, overlapped.makespan,
        ));
        last_summary = Some(summary);

        // acceptance: 1F1B overlaps (strictly beats single-slot) and its
        // bubble sits on the analytic (p-1)/(m+p-1) curve
        assert!(
            overlapped.makespan < serial.makespan,
            "p={p}: 1f1b {} did not beat unoverlapped {}",
            overlapped.makespan,
            serial.makespan
        );
        assert!(
            (measured - ideal).abs() < 0.05,
            "p={p}: measured bubble {measured:.4} off the ideal {ideal:.4}"
        );
    }
    tab.print();

    let json = format!(
        "{{\n  \"bench\": \"pipeline_1f1b\",\n  \"quick\": {quick},\n  \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::write("BENCH_pipeline_1f1b.json", &json).expect("write BENCH_pipeline_1f1b.json");
    println!("\nwrote BENCH_pipeline_1f1b.json");

    // the deepest traced point's timeline, reduced to the machine-readable
    // schedule observability artifact CI checks for
    if let Some(s) = last_summary {
        s.write_json("TRACE_summary.json").expect("write TRACE_summary.json");
        println!("wrote TRACE_summary.json");
    }
}
