//! Table 2 reproduction: measured bytes moved by every boxing transition vs
//! the paper's formulas, same-placement (p=4) and disjoint (4 -> 2) columns —
//! plus (ISSUE 4) the *lowered* transfer plans: per-member ring shares and
//! routed per-route bytes must land on the same closed forms.

use oneflow::bench::Table;
use oneflow::boxing::{apply_boxing, cost, plan_transfer};
use oneflow::placement::Placement;
use oneflow::sbp::{s, scatter, NdSbp, B, P};
use oneflow::tensor::{DType, Tensor};
use oneflow::util::Rng;

fn main() {
    let sigs = [s(0), s(1), B, P];
    let name = |x: oneflow::sbp::Sbp| x.to_string();
    let mut rng = Rng::new(1);
    let t = Tensor::randn([64, 64], DType::F32, 1.0, &mut rng);
    let t_bytes = t.bytes() as f64;

    let mut tab = Table::new(
        "Table 2 — bytes transferred per SBP transition (|T| = 16 KiB, p1=4, p2=2)",
        &["transition", "measured (same)", "formula (same)", "measured (disjoint)", "formula (disjoint)"],
    );
    let p_same = Placement::node(0, 4);
    let p_out = Placement::node(1, 2);
    for &a in &sigs {
        for &b in &sigs {
            let in_nd = NdSbp::d1(a);
            let out_nd = NdSbp::d1(b);
            let shards = scatter(&t, &in_nd, &[4]);
            let same = apply_boxing(&shards, &in_nd, &p_same, &out_nd, &p_same);
            let disj = apply_boxing(&shards, &in_nd, &p_same, &out_nd, &p_out);
            let f_same = cost::transfer_bytes(a, b, 4, 4, true, t_bytes);
            let f_disj = cost::transfer_bytes(a, b, 4, 2, false, t_bytes);
            assert_eq!(same.bytes_moved, f_same, "{a}->{b} same");
            assert_eq!(disj.bytes_moved, f_disj, "{a}->{b} disjoint");
            tab.row(&[
                format!("{} -> {}", name(a), name(b)),
                format!("{:.0}", same.bytes_moved),
                format!("{:.0}", f_same),
                format!("{:.0}", disj.bytes_moved),
                format!("{:.0}", f_disj),
            ]);
        }
    }
    tab.print();
    println!("\nall 32 cells match Table 2 exactly");

    // ---- lowered-plan parity (ISSUE 4) ----
    // Aligned same-placement edges lower to per-member ring ops; every
    // member's analytic share times the member count is the Table 2 total.
    for &a in &sigs {
        for &b in &sigs {
            let per_member = cost::member_bytes_same(&NdSbp::d1(a), &NdSbp::d1(b), &[4], t_bytes);
            assert_eq!(
                per_member * 4.0,
                cost::transfer_bytes(a, b, 4, 4, true, t_bytes),
                "{a} -> {b} ring member share"
            );
        }
    }
    // Cross-placement edges lower to routed sub-plans (with a producer-side
    // LocalReduce hop for partial inputs): the sum of route bytes that cross
    // devices equals the disjoint column exactly.
    for &a in &sigs {
        for &b in &sigs {
            let hops =
                plan_transfer(&NdSbp::d1(a), &p_same, &NdSbp::d1(b), &p_out, &t.shape, 4.0);
            let moved: f64 = hops.iter().map(|h| h.crossing_bytes()).sum();
            assert_eq!(
                moved,
                cost::transfer_bytes(a, b, 4, 2, false, t_bytes),
                "{a} -> {b} routed bytes"
            );
        }
    }
    println!("lowered ring-member shares and routed route bytes match Table 2 ✓");
}
