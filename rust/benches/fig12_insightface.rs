//! Fig 12 reproduction: model-parallel face-recognition training throughput,
//! OneFlow's compiler-generated plan vs InsightFace's manual plan, for the
//! two backbones. Paper shape: OneFlow slightly ahead (same physical plan;
//! the delta is fusion + runtime overhead).

use oneflow::actor::Engine;
use oneflow::baselines::Framework;
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::models::insightface::{insightface, Backbone};
use oneflow::placement::Placement;
use oneflow::runtime::SimBackend;
use oneflow::tensor::DType;
use std::sync::Arc;

fn main() {
    let mut tab = Table::new(
        "Fig 12 — InsightFace model parallelism (samples/s, 1M ids)",
        &["backbone", "GPUs", "OneFlow", "InsightFace lib", "ratio"],
    );
    for backbone in [Backbone::Resnet100, Backbone::MobileFaceNet] {
        for ndev in [8usize, 16, 32] {
            let pl = Placement::flat(ndev.div_ceil(8), ndev.min(8));
            let batch = 64;
            let mut tput = vec![];
            for fw in [Framework::OneFlow, Framework::InsightFaceLib] {
                let (g, loss, upd) = insightface(backbone, 1_000_000, batch, &pl, DType::F16);
                let plan = compile(&g, &[loss], &upd, &fw.compile_options());
                let report = Engine::new(plan, Arc::new(SimBackend)).run(4);
                tput.push(report.throughput() * (batch * ndev) as f64);
            }
            tab.row(&[
                format!("{backbone:?}"),
                ndev.to_string(),
                format!("{:.0}", tput[0]),
                format!("{:.0}", tput[1]),
                format!("{:.2}x", tput[0] / tput[1]),
            ]);
        }
    }
    tab.print();
    println!("\npaper shape: OneFlow slightly outperforms the manual plan at every scale");
}
