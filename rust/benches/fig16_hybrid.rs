//! Fig 16 reproduction: GPT-2 per-iteration time under the four parallelism
//! regimes vs Megatron-LM. Tuples are (dp, mp, pp, global-batch, hidden,
//! layers) as under the paper's figure. Paper shape: OneFlow ≤ Megatron in
//! every regime, including single-device (more kernel fusion).

use oneflow::actor::Engine;
use oneflow::baselines::Framework;
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::models::{gpt_sim, GptSimConfig};
use oneflow::runtime::SimBackend;
use oneflow::util::fmt;
use std::sync::Arc;

fn main() {
    let cases: Vec<(&str, GptSimConfig)> = vec![
        ("pure data parallel (8,1,1,64,1536,16)", GptSimConfig::new(8, 1, 1, 64, 1536, 16)),
        ("pure model parallel (1,8,1,8,3072,16)", GptSimConfig::new(1, 8, 1, 8, 3072, 16)),
        ("data+model 2D (2,8,1,16,3072,16)", GptSimConfig::new(2, 8, 1, 16, 3072, 16)),
        ("data+model+pipeline (2,8,2,64,3072,32)", {
            let mut c = GptSimConfig::new(2, 8, 2, 64, 3072, 32);
            c.checkpoint = true;
            c
        }),
        ("single device (1,1,1,8,768,12)", GptSimConfig::new(1, 1, 1, 8, 768, 12)),
    ];
    let mut tab = Table::new(
        "Fig 16 — GPT-2 per-iteration time: OneFlow vs Megatron-LM",
        &["config", "OneFlow", "Megatron-LM", "speedup"],
    );
    for (name, cfg) in cases {
        let mut times = vec![];
        for fw in [Framework::OneFlow, Framework::MegatronLm] {
            let (g, loss, upd) = gpt_sim(&cfg);
            let plan = compile(&g, &[loss], &upd, &fw.compile_options());
            let pieces = 3;
            let report = Engine::new(plan, Arc::new(SimBackend)).run(pieces);
            times.push(report.makespan / pieces as f64);
        }
        tab.row(&[
            name.into(),
            fmt::secs(times[0]),
            fmt::secs(times[1]),
            format!("{:.2}x", times[1] / times[0]),
        ]);
    }
    tab.print();
    println!("\npaper shape: OneFlow ahead in every regime, already on a single device");
}
