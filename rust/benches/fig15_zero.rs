//! Fig 15 reproduction: optimizer-state sharding (ZeRO-DP) — per-device
//! memory and throughput for GPT-2, activation checkpointing on/off,
//! OneFlow's SBP formulation vs the DeepSpeed ZeRO-DP profile.
//! Paper shape: OneFlow uses less memory and is a bit faster in all four
//! quadrants; sharded states cut memory multiples.

use oneflow::actor::Engine;
use oneflow::baselines::Framework;
use oneflow::bench::Table;
use oneflow::compiler::compile;
use oneflow::memory::{ModelStates, OptimKind, StateLayout};
use oneflow::models::{gpt_sim, GptSimConfig};
use oneflow::runtime::SimBackend;
use oneflow::util::fmt;
use std::sync::Arc;

fn main() {
    let ndev = 8;
    let mut tab = Table::new(
        "Fig 15 — GPT-2 (772M) optimizer sharding on 8 GPUs",
        &["system", "ckpt", "state+act mem/GPU", "iteration time"],
    );
    for ckpt in [false, true] {
        for (fwname, fw, zero) in [
            ("OneFlow ZeRO-sbp", Framework::OneFlow, true),
            ("ZeRO-DP (DeepSpeed)", Framework::ZeroDp, true),
            ("plain DP (no sharding)", Framework::OneFlow, false),
        ] {
            let mut cfg = GptSimConfig::new(ndev, 1, 1, 16, 1536, 24);
            cfg.zero = zero;
            cfg.checkpoint = ckpt;
            let (g, loss, upd) = gpt_sim(&cfg);
            let plan = compile(&g, &[loss], &upd, &fw.compile_options());
            let report = Engine::new(plan, Arc::new(SimBackend)).run(4);

            let states = ModelStates {
                params: cfg.params(),
                n_devices: ndev,
                mixed_precision: true,
                optim: OptimKind::Adam,
                layout: if zero { StateLayout::ZeroSharded } else { StateLayout::Replicated },
            };
            // ZeRO-DP (pytorch) keeps extra flat fp32 buffers (+2 bytes/param)
            let extra = if fwname.starts_with("ZeRO-DP") { 2.0 * cfg.params() } else { 0.0 };
            let mem = states.state_bytes_per_device()
                + states.transformer_activation_bytes(
                    cfg.global_batch / ndev,
                    cfg.seq,
                    cfg.hidden,
                    cfg.layers,
                    ckpt,
                )
                + extra;
            tab.row(&[
                fwname.into(),
                if ckpt { "on" } else { "off" }.into(),
                fmt::bytes(mem),
                fmt::secs(report.makespan / 4.0),
            ]);
        }
    }
    tab.print();
    println!("\npaper shape: OneFlow < ZeRO-DP memory at same sharding; ckpt trades time for memory");
}
