"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO **text** artifacts the rust
runtime loads via PJRT (`rust/src/runtime/pjrt.rs`).

Text, not `.serialize()`: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the bundled xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--dp N] [--layers L] ...

Emits:
    artifacts/gpt_train.hlo.txt   train step for one dp shard:
                                  (params..., ids, labels) ->
                                  (loss_vec, grads...)
    artifacts/gpt_meta.json       shapes/dtypes/param list for the rust side
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import GptConfig, train_step_sum_grads


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--dp", type=int, default=1, help="data-parallel shards")
    ap.add_argument("--batch", type=int, default=8, help="global batch size")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    args = ap.parse_args()

    cfg = GptConfig(
        vocab=args.vocab,
        seq=args.seq,
        d_model=args.d_model,
        n_layers=args.layers,
        n_heads=args.heads,
    )
    assert args.batch % args.dp == 0, "global batch must divide by dp"
    shard_b = args.batch // args.dp

    os.makedirs(args.out, exist_ok=True)

    def step(*flat):
        n = len(cfg.param_shapes())
        params = list(flat[:n])
        ids, labels = flat[n], flat[n + 1]
        return tuple(train_step_sum_grads(params, ids, labels, cfg))

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in cfg.param_shapes()]
    specs.append(jax.ShapeDtypeStruct((shard_b, cfg.seq), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((shard_b, cfg.seq), jnp.int32))
    lowered = jax.jit(step).lower(*specs)
    text = to_hlo_text(lowered)
    path = os.path.join(args.out, "gpt_train.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    meta = {
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "dp": args.dp,
        "global_batch": args.batch,
        "shard_batch": shard_b,
        "param_shapes": [list(s) for s in cfg.param_shapes()],
        "param_count": int(cfg.param_count()),
        "artifact": "gpt_train.hlo.txt",
        "outputs": "loss_vec(shard_b*seq), then grads per param (summed loss)",
    }
    with open(os.path.join(args.out, "gpt_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(
        f"wrote {path} ({len(text) / 1e6:.1f} MB HLO text), "
        f"{meta['param_count'] / 1e6:.2f}M params, shard batch {shard_b}"
    )


if __name__ == "__main__":
    main()
