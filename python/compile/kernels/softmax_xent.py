"""L1 Pallas kernel: sparse softmax cross-entropy with *local reduction*.

This is the paper's Fig 11b structure on TPU terms: the class dimension is
tiled; each grid step reduces its tile **locally in VMEM** (running max `m`,
running scaled sum `s` — the online-LSE recurrence) and only the tiny (bb,)
statistics persist across tiles. When the class dim is `S(1)`-sharded across
devices, the rust compiler maps the cross-device halves of exactly these two
reductions to `P(max)`/`P(sum)` boxing collectives — the kernel and the
collective implement the same algebra at two levels of the hierarchy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(logits_ref, labels_ref, loss_ref, m_ref, s_ref, ll_ref, *, nv, bv):
    """Grid (B/bb, V/bv), class-tile innermost. Running stats live in output
    blocks that persist across the inner dimension."""
    v = pl.program_id(1)

    @pl.when(v == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        ll_ref[...] = jnp.zeros_like(ll_ref)

    tile = logits_ref[...]  # (bb, bv)
    # online logsumexp: local max then rescale the running sum
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, tile.max(axis=1))
    s_ref[...] = s_ref[...] * jnp.exp(m_old - m_new) + jnp.exp(
        tile - m_new[:, None]
    ).sum(axis=1)
    m_ref[...] = m_new
    # pick out the label logit if it falls in this tile
    labels = labels_ref[...]
    local = labels - v * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    hit = cols == local[:, None]
    ll_ref[...] += jnp.where(hit, tile, 0.0).sum(axis=1)

    @pl.when(v == nv - 1)
    def _finalize():
        loss_ref[...] = m_ref[...] + jnp.log(s_ref[...]) - ll_ref[...]


def _pick_block(n, target):
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bb", "bv"))
def softmax_xent_fwd(logits, labels, bb=8, bv=128):
    """Per-example loss `(B,)` for `logits (B, V)` and int32 `labels (B,)`."""
    bdim, v = logits.shape
    bb = _pick_block(bdim, bb)
    bv = _pick_block(v, bv)
    nv = v // bv
    grid = (bdim // bb, nv)
    loss, _, _, _ = pl.pallas_call(
        functools.partial(_kernel, nv=nv, bv=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bv), lambda i, v: (i, v)),
            pl.BlockSpec((bb,), lambda i, v: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, v: (i,)),
            pl.BlockSpec((bb,), lambda i, v: (i,)),
            pl.BlockSpec((bb,), lambda i, v: (i,)),
            pl.BlockSpec((bb,), lambda i, v: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bdim,), jnp.float32),  # loss
            jax.ShapeDtypeStruct((bdim,), jnp.float32),  # running max
            jax.ShapeDtypeStruct((bdim,), jnp.float32),  # running sum
            jax.ShapeDtypeStruct((bdim,), jnp.float32),  # label logit
        ],
        interpret=True,
    )(logits, labels.astype(jnp.int32))
    return loss


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Differentiable sparse softmax cross-entropy (Pallas forward)."""
    return softmax_xent_fwd(logits, labels)


def _fwd(logits, labels):
    return softmax_xent_fwd(logits, labels), (logits, labels)


def _bwd(res, dy):
    logits, labels = res
    p = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return ((p - onehot) * dy[:, None], None)


softmax_xent.defvjp(_fwd, _bwd)
