"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest compares kernel output and gradients against these)."""

import jax
import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654


def gelu(y):
    """tanh-approximation GELU (matches the rust native kernel)."""
    return 0.5 * y * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (y + 0.044715 * y**3)))


def fused_linear_ref(x, w, b, act="gelu"):
    y = x @ w + b[None, :]
    if act == "gelu":
        return gelu(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    return y


def softmax_xent_ref(logits, labels):
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse - ll
