"""L1 Pallas kernel: fused MatMul + bias + activation.

The TPU re-think of the CUDA fused-GEMM epilogue (DESIGN.md
§Hardware-Adaptation): BlockSpecs move (bm, bk) x (bk, bn) tiles HBM->VMEM;
the K grid dimension is innermost ("arbitrary" semantics) so the output tile
stays resident in VMEM as the accumulator across the reduction — the role
the threadblock's shared-memory accumulator plays on GPU — and the bias +
activation epilogue runs once, in-register, when the last K tile retires.
This is the kernel-fusion mechanism the paper credits for OneFlow's
single-device edge over Megatron-LM (§6.5).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is *estimated* from the BlockSpec (see
EXPERIMENTS.md §Perf and `vmem_footprint_bytes`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT_2_OVER_PI = 0.7978845608028654


def _gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (y + 0.044715 * y * y * y)))


def _apply_act(y, act):
    if act == "gelu":
        return _gelu(y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    return y


def _kernel(x_ref, w_ref, b_ref, o_ref, *, nk, act):
    """One (i, j, k) grid step: accumulate into the resident output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...][None, :], act)


def _pick_block(n, target):
    """Largest divisor of n that is <= target (tiles must divide evenly)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk"))
def fused_matmul_bias_act(x, w, b, act="gelu", bm=128, bn=128, bk=128):
    """`act(x @ w + b)` as a single Pallas kernel (forward only).

    Block sizes default to the MXU-friendly 128 and shrink to divisors of the
    problem for small shapes.
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2 and b.shape == (n,)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(kdim, bk)
    nk = kdim // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, act="gelu"):
    """Differentiable fused linear layer: Pallas forward, analytic backward."""
    return fused_matmul_bias_act(x, w, b, act)


def _fwd(x, w, b, act):
    return fused_matmul_bias_act(x, w, b, act), (x, w, b)


def _bwd(act, res, dy):
    x, w, b = res
    pre = x @ w + b[None, :]
    if act == "gelu":
        u = SQRT_2_OVER_PI * (pre + 0.044715 * pre**3)
        t = jnp.tanh(u)
        du = SQRT_2_OVER_PI * (1.0 + 3 * 0.044715 * pre * pre)
        dact = 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t * t) * du
    elif act == "relu":
        dact = (pre > 0).astype(pre.dtype)
    else:
        dact = jnp.ones_like(pre)
    dpre = dy * dact
    return dpre @ w.T, x.T @ dpre, dpre.sum(axis=0)


fused_linear.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(bm=128, bn=128, bk=128, dtype_bytes=4):
    """Estimated VMEM residency of one grid step: x tile + w tile + out tile
    (+ bias). Used by EXPERIMENTS.md §Perf to check the BlockSpec fits the
    ~16 MiB VMEM budget of a TPU core with double-buffering headroom."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn + bn)


def mxu_utilization_estimate(bm=128, bn=128, bk=128):
    """Fraction of MXU-issue slots doing useful work for one step, assuming
    128x128 systolic tiles: full when all block dims are multiples of 128."""
    eff = 1.0
    for d in (bm, bn, bk):
        eff *= min(d, 128) / 128.0 if d < 128 else 1.0
    return eff
