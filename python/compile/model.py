"""L2: the GPT model (forward + backward) in JAX, calling the L1 Pallas
kernels. Lowered once by `aot.py`; never imported at runtime.

The parameter list is a *flat, deterministically-ordered* list of arrays so
the rust coordinator can bind each one to a Variable actor:

    [tok_emb (V,D), pos_emb (T,D)] + per block:
    [wqkv (D,3D), wproj (D,D), w1 (D,4H), b1 (4H,), w2 (4H,D), b2 (D,)]

The LM head is weight-tied to `tok_emb`.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.fused_matmul import fused_linear
from .kernels.softmax_xent import softmax_xent


@dataclass(frozen=True)
class GptConfig:
    vocab: int = 256
    seq: int = 64
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4

    @property
    def d_ff(self):
        return 4 * self.d_model

    def param_shapes(self):
        shapes = [(self.vocab, self.d_model), (self.seq, self.d_model)]
        for _ in range(self.n_layers):
            shapes += [
                (self.d_model, 3 * self.d_model),
                (self.d_model, self.d_model),
                (self.d_model, self.d_ff),
                (self.d_ff,),
                (self.d_ff, self.d_model),
                (self.d_model,),
            ]
        return shapes

    def param_count(self):
        return sum(int(jnp.prod(jnp.array(s))) for s in self.param_shapes())


def init_params(cfg: GptConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        std = 0.0 if len(shape) == 1 else 0.02
        params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def _layernorm(x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def _attention(x, wqkv, wproj, n_heads):
    b, t, d = x.shape
    qkv = x @ wqkv  # (B,T,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads

    def heads(z):
        return z.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wproj


def forward(params, ids, cfg: GptConfig):
    """ids (B, T) int32 -> logits (B*T, V)."""
    tok_emb, pos_emb = params[0], params[1]
    b, t = ids.shape
    x = tok_emb[ids] + pos_emb[None, :t, :]
    idx = 2
    for _ in range(cfg.n_layers):
        wqkv, wproj, w1, b1, w2, b2 = params[idx : idx + 6]
        idx += 6
        x = x + _attention(_layernorm(x), wqkv, wproj, cfg.n_heads)
        h = _layernorm(x).reshape(b * t, cfg.d_model)
        # the L1 fused Pallas kernel: matmul + bias + GELU in one launch
        h = fused_linear(h, w1, b1, "gelu")
        h = h @ w2 + b2[None, :]
        x = x + h.reshape(b, t, cfg.d_model)
    x = _layernorm(x).reshape(b * t, cfg.d_model)
    return x @ tok_emb.T  # tied head -> (B*T, V)


def loss_vec(params, ids, labels, cfg: GptConfig):
    """Per-token loss (B*T,) via the L1 Pallas softmax-xent kernel."""
    logits = forward(params, ids, cfg)
    return softmax_xent(logits, labels.reshape(-1))


def train_step_sum_grads(params, ids, labels, cfg: GptConfig):
    """Returns (per-token loss vector, grads of the *summed* loss).

    Summed (not mean) so that data-parallel shards compose: the coordinator
    scales by 1/global_tokens and all-reduces — grads are `P(sum)` exactly.
    """

    def total(ps):
        lv = loss_vec(ps, ids, labels, cfg)
        return lv.sum(), lv

    (_, lv), grads = jax.value_and_grad(total, has_aux=True)(params)
    return [lv] + list(grads)
