"""L1 correctness: Pallas kernels vs pure-jnp oracles — values and grads.
Hypothesis sweeps shapes; fixed cases pin the block-edge paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_matmul import (
    fused_linear,
    fused_matmul_bias_act,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import fused_linear_ref, softmax_xent_ref
from compile.kernels.softmax_xent import softmax_xent, softmax_xent_fwd


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestFusedMatmul:
    @pytest.mark.parametrize("act", ["gelu", "relu", "none"])
    @pytest.mark.parametrize("shape", [(8, 16, 8), (128, 128, 128), (64, 256, 32), (13, 7, 5)])
    def test_matches_ref(self, act, shape):
        m, k, n = shape
        x, w, b = rand(1, m, k), rand(2, k, n), rand(3, n)
        got = fused_matmul_bias_act(x, w, b, act)
        want = fused_linear_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 40),
        k=st.integers(1, 40),
        n=st.integers(1, 40),
        seed=st.integers(0, 2**16),
        act=st.sampled_from(["gelu", "relu", "none"]),
    )
    def test_shape_sweep(self, m, k, n, seed, act):
        x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
        got = fused_matmul_bias_act(x, w, b, act)
        want = fused_linear_ref(x, w, b, act)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_grads_match_ref(self):
        x, w, b = rand(5, 16, 12), rand(6, 12, 8), rand(7, 8)

        def f_kernel(x, w, b):
            return fused_linear(x, w, b, "gelu").sum()

        def f_ref(x, w, b):
            return fused_linear_ref(x, w, b, "gelu").sum()

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
        for a, c in zip(gk, gr):
            np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)

    def test_custom_block_sizes(self):
        x, w, b = rand(8, 64, 64), rand(9, 64, 64), rand(10, 64)
        for bm, bn, bk in [(16, 16, 16), (32, 64, 8), (64, 64, 64)]:
            got = fused_matmul_bias_act(x, w, b, "gelu", bm=bm, bn=bn, bk=bk)
            want = fused_linear_ref(x, w, b, "gelu")
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_vmem_budget(self):
        # default BlockSpec must fit a TPU core's ~16 MiB VMEM with
        # double-buffering headroom (< 8 MiB resident)
        assert vmem_footprint_bytes() < 8 * 1024 * 1024
        assert mxu_utilization_estimate() == 1.0
        assert mxu_utilization_estimate(bm=64) == 0.5


class TestSoftmaxXent:
    @pytest.mark.parametrize("shape", [(8, 256), (4, 128), (16, 512), (3, 50)])
    def test_matches_ref(self, shape):
        b, v = shape
        logits = rand(11, b, v) * 3.0
        labels = jax.random.randint(jax.random.PRNGKey(12), (b,), 0, v)
        got = softmax_xent_fwd(logits, labels)
        want = softmax_xent_ref(logits, labels)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 24), v=st.integers(2, 300), seed=st.integers(0, 2**16))
    def test_shape_sweep(self, b, v, seed):
        logits = rand(seed, b, v) * 2.0
        labels = jax.random.randint(jax.random.PRNGKey(seed + 1), (b,), 0, v)
        got = softmax_xent_fwd(logits, labels)
        want = softmax_xent_ref(logits, labels)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_numerical_stability_large_logits(self):
        logits = rand(13, 4, 64) * 200.0  # would overflow naive exp
        labels = jnp.array([0, 5, 9, 63])
        got = softmax_xent_fwd(logits, labels)
        want = softmax_xent_ref(logits, labels)
        assert jnp.isfinite(got).all()
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_grads_match_ref(self):
        logits = rand(14, 6, 96)
        labels = jax.random.randint(jax.random.PRNGKey(15), (6,), 0, 96)

        gk = jax.grad(lambda l: softmax_xent(l, labels).sum())(logits)
        gr = jax.grad(lambda l: softmax_xent_ref(l, labels).sum())(logits)
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def test_perfect_prediction_low_loss(self):
        v = 32
        labels = jnp.arange(4) % v
        logits = jax.nn.one_hot(labels, v) * 50.0
        loss = softmax_xent_fwd(logits, labels)
        assert (loss < 1e-3).all()
