"""L2 correctness: model shapes, differentiability, and that a few SGD steps
on a fixed batch reduce the loss (the sanity signal before AOT export)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import GptConfig, forward, init_params, loss_vec, train_step_sum_grads

TINY = GptConfig(vocab=64, seq=16, d_model=32, n_layers=2, n_heads=2)


def batch(cfg, b=4, seed=0):
    key = jax.random.PRNGKey(seed)
    ids = jax.random.randint(key, (b, cfg.seq), 0, cfg.vocab)
    labels = jnp.roll(ids, -1, axis=1)
    return ids, labels


def test_param_shapes_and_count():
    params = init_params(TINY)
    assert len(params) == 2 + 6 * TINY.n_layers
    assert params[0].shape == (64, 32)
    assert TINY.param_count() == sum(int(np.prod(p.shape)) for p in params)


def test_forward_shapes():
    params = init_params(TINY)
    ids, _ = batch(TINY)
    logits = forward(params, ids, TINY)
    assert logits.shape == (4 * TINY.seq, TINY.vocab)
    assert jnp.isfinite(logits).all()


def test_loss_vec_shape_and_range():
    params = init_params(TINY)
    ids, labels = batch(TINY)
    lv = loss_vec(params, ids, labels, TINY)
    assert lv.shape == (4 * TINY.seq,)
    # near-uniform prediction at init: loss ~ ln(vocab)
    assert abs(lv.mean() - np.log(TINY.vocab)) < 0.5


def test_train_step_output_arity():
    params = init_params(TINY)
    ids, labels = batch(TINY)
    outs = train_step_sum_grads(params, ids, labels, TINY)
    assert len(outs) == 1 + len(params)
    for g, p in zip(outs[1:], params):
        assert g.shape == p.shape


def test_sgd_reduces_loss_on_fixed_batch():
    params = init_params(TINY)
    ids, labels = batch(TINY)
    ntok = ids.size

    @jax.jit
    def step(params):
        outs = train_step_sum_grads(params, ids, labels, TINY)
        lv, grads = outs[0], outs[1:]
        new = [p - 0.5 / ntok * g for p, g in zip(params, grads)]
        return new, lv.mean()

    losses = []
    for _ in range(8):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.1, f"no learning: {losses}"


def test_grads_sum_semantics():
    # grads of the SUMMED loss: doubling the batch by concatenation must give
    # the sum of shard grads — the P(sum) data-parallel contract.
    params = init_params(TINY)
    ids1, lab1 = batch(TINY, b=2, seed=1)
    ids2, lab2 = batch(TINY, b=2, seed=2)
    g1 = train_step_sum_grads(params, ids1, lab1, TINY)[1:]
    g2 = train_step_sum_grads(params, ids2, lab2, TINY)[1:]
    gall = train_step_sum_grads(
        params, jnp.concatenate([ids1, ids2]), jnp.concatenate([lab1, lab2]), TINY
    )[1:]
    for a, b2, c in zip(g1, g2, gall):
        np.testing.assert_allclose(a + b2, c, rtol=2e-3, atol=2e-4)
