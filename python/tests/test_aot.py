"""AOT path: lowering the train step to HLO text must succeed and the text
must contain an entry computation with the right parameter count."""

import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.model import GptConfig, train_step_sum_grads

TINY = GptConfig(vocab=32, seq=8, d_model=16, n_layers=1, n_heads=2)


def test_lower_train_step_to_hlo_text():
    n = len(TINY.param_shapes())

    def step(*flat):
        params = list(flat[:n])
        ids, labels = flat[n], flat[n + 1]
        return tuple(train_step_sum_grads(params, ids, labels, TINY))

    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in TINY.param_shapes()]
    specs.append(jax.ShapeDtypeStruct((2, TINY.seq), jnp.int32))
    specs.append(jax.ShapeDtypeStruct((2, TINY.seq), jnp.int32))
    text = to_hlo_text(jax.jit(step).lower(*specs))
    assert "HloModule" in text
    assert "ENTRY" in text
    # all params + ids + labels appear as entry parameters
    assert text.count("parameter(") >= n + 2
